"""Generic decoder LM covering dense / moe / ssm (RWKV-6) / hybrid (Jamba) / vlm.

The layer stack is executed as a ``lax.scan`` over *layer groups* so HLO size is
O(1) in depth (critical for the 80 dry-run compiles on one CPU core):
  * homogeneous archs: group_size = 1
  * gemma3: group_size = 6 (5 local + 1 global)
  * jamba:  group_size = 8 (attention at index 4, Mamba elsewhere, MoE on odd)
Sub-layer kind depends only on the position *within* the group, so one traced
group body serves every group.
"""
from __future__ import annotations

import functools
from collections import Counter
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (DENSE, HYBRID, MOE, SSM, VLM, ModelConfig)
from repro.layers import attention as attn
from repro.layers import mamba as mamba_mod
from repro.layers import mla as mla_mod
from repro.layers import rwkv6 as rwkv_mod
from repro.layers.core import (embed, init_embedding, init_mlp, init_rmsnorm,
                               mlp, rms_norm, unembed)
from repro.layers.moe import init_moe, moe_apply


# ---------------------------------------------------------------------------
# Group structure
# ---------------------------------------------------------------------------
def group_size(cfg: ModelConfig) -> int:
    if cfg.family == HYBRID:
        return cfg.hybrid.period
    if cfg.global_layer_every > 0:
        return cfg.global_layer_every
    return 1


def n_groups(cfg: ModelConfig) -> int:
    gs = group_size(cfg)
    assert cfg.n_layers % gs == 0, (cfg.name, cfg.n_layers, gs)
    return cfg.n_layers // gs


def mixer_kind(cfg: ModelConfig, i: int) -> str:
    """Sequence mixer of sub-layer i (position within group)."""
    if cfg.family == SSM:
        return "rwkv"
    if cfg.family == HYBRID:
        return "attn" if i == cfg.hybrid.attn_index else "mamba"
    if cfg.mla is not None:
        return "mla"
    if cfg.global_layer_every > 0:
        return "attn" if (i + 1) % cfg.global_layer_every == 0 else "attn_local"
    if cfg.sliding_window > 0:
        return "attn_local"
    return "attn"


def ffn_kind(cfg: ModelConfig, i: int) -> Optional[str]:
    if cfg.family == SSM:
        return None                       # channel-mix lives inside the rwkv block
    if cfg.moe is not None and (i % cfg.moe.moe_every) == (cfg.moe.moe_every - 1):
        return "moe"
    return "mlp"


def layer_window(cfg: ModelConfig, i: int) -> int:
    return cfg.sliding_window if mixer_kind(cfg, i) == "attn_local" else 0


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------
def _init_sublayer(key, cfg: ModelConfig, i: int) -> dict:
    kind = mixer_kind(cfg, i)
    k1, k2, k3 = jax.random.split(key, 3)
    dt = cfg.dtype()
    p: dict = {"n1": init_rmsnorm(cfg.d_model, dt)}
    if kind == "rwkv":
        p["mix"] = rwkv_mod.init_rwkv_layer(k1, cfg)
        p["n2"] = init_rmsnorm(cfg.d_model, dt)
        return p
    if kind == "mamba":
        p["mix"] = mamba_mod.init_mamba_layer(k1, cfg)
    elif kind == "mla":
        p["mix"] = mla_mod.init_mla(k1, cfg)
    else:
        p["mix"] = attn.init_attention(k1, cfg)
    fk = ffn_kind(cfg, i)
    if fk:
        p["n2"] = init_rmsnorm(cfg.d_model, dt)
        p["ffn"] = init_moe(k2, cfg) if fk == "moe" else init_mlp(k2, cfg)
    return p


def init_group(key, cfg: ModelConfig) -> dict:
    gs = group_size(cfg)
    keys = jax.random.split(key, gs)
    return {f"sub{i}": _init_sublayer(keys[i], cfg, i) for i in range(gs)}


def init_params(key, cfg: ModelConfig) -> dict:
    ke, kb, kf = jax.random.split(key, 3)
    G = n_groups(cfg)
    blocks = jax.vmap(lambda k: init_group(k, cfg))(jax.random.split(kb, G))
    return {
        "embed": init_embedding(ke, cfg),
        "blocks": blocks,
        "final_norm": init_rmsnorm(cfg.d_model, cfg.dtype()),
    }


def param_specs(cfg: ModelConfig) -> Any:
    """Abstract param shapes (no allocation) for the dry-run."""
    return jax.eval_shape(lambda k: init_params(k, cfg),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


# ---------------------------------------------------------------------------
# Decode caches
# ---------------------------------------------------------------------------
def _sublayer_cache(cfg: ModelConfig, i: int, batch: int, seq: int, dtype):
    kind = mixer_kind(cfg, i)
    if kind == "rwkv":
        return rwkv_mod.init_rwkv_state(cfg, batch, dtype)
    if kind == "mamba":
        return mamba_mod.init_mamba_state(cfg, batch, dtype)
    if kind == "mla":
        return mla_mod.make_mla_cache(cfg, batch, seq, dtype)
    return attn.make_kv_cache(cfg, batch, seq, layer_window(cfg, i), dtype)


def init_decode_state(cfg: ModelConfig, batch: int, seq: int, dtype=None):
    """Stacked (over groups) cache pytree."""
    dt = dtype or jnp.dtype(cfg.compute_dtype)
    gs = group_size(cfg)
    one = {f"sub{i}": _sublayer_cache(cfg, i, batch, seq, dt) for i in range(gs)}
    G = n_groups(cfg)
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (G,) + x.shape), one)


def decode_state_specs(cfg: ModelConfig, batch: int, seq: int, dtype=None):
    return jax.eval_shape(
        functools.partial(init_decode_state, cfg, batch, seq, dtype))


def supports_paged(cfg: ModelConfig) -> bool:
    """True when the request's ENTIRE dynamic context can live on AquaTensor
    pages — i.e. every sub-layer's state has a page plane in
    :func:`paged_layout`: full (unwindowed, uncapped) GQA/MQA attention KV,
    Mamba ssm/conv tails, RWKV6 wkv + token-shift state, or the MLA latent
    cache. Ring-buffer windowed layers and encoder-decoder stacks are the
    only remaining exceptions (ROADMAP follow-up)."""
    if cfg.family not in (DENSE, MOE, VLM, SSM, HYBRID):
        return False
    if cfg.attn_logit_softcap > 0:
        return False
    gs = group_size(cfg)
    return all(mixer_kind(cfg, i) in ("attn", "rwkv", "mamba", "mla")
               and layer_window(cfg, i) == 0 for i in range(gs))


def paged_layout(cfg: ModelConfig) -> dict:
    """Map every dynamic-context leaf of the family onto a page PLANE.

    A plane is one AquaTensor pool; every sub-layer position (within the
    layer group) contributes its state leaves to the planes listed here, in
    group order. Two plane kinds:

      * ``tokens`` — grows with context, ``ceil(ctx/page_tokens)`` pages per
        layer. ``kv``: payload ``(2, n_kv, page, hd)`` (attention K/V);
        ``mla``: payload ``(page, kv_lora + rope_dim)`` (fused latent+rope).
      * ``state``  — fixed-size recurrent state, ONE page per layer whose
        payload is exactly the leaf. ``ssm``: ``(di, ds)`` f32; ``conv``:
        ``(d_conv-1, di)`` native; ``wkv``: ``(H, hd, hd)`` f32; ``shift``:
        ``(2, d_model)`` native (rows: time-mix / channel-mix shifts).

    Token planes are SHAREABLE (``"shareable": True``): their pages are
    position-addressed and immutable once prefill has written them, so two
    requests with a common page-aligned prompt prefix can alias the same
    physical pages and a prefill chunk may start past the shared prefix
    (``q_start > 0`` on its first chunk — the block tables carry the shared
    pages, so attention/MLA reads cover them without recomputation). State
    planes are NOT shareable: a recurrent state page is rewritten on every
    chunk/decode step and summarizes the whole prefix, so the runtime
    disables prefix sharing for any family that owns one.

    Returns ``{name: {"kind", "positions", "dtype", "shareable", ...}}``
    where token planes carry ``dims`` + ``token_bytes`` and state planes
    carry ``shape``.
    """
    assert supports_paged(cfg), f"{cfg.name}: not paged-servable"
    from repro.layers import mamba as _mam
    native = jnp.dtype(cfg.compute_dtype)
    planes: dict = {}

    def add(name, i, **kw):
        planes.setdefault(name, dict(positions=[], **kw))["positions"].append(i)

    K, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    for i in range(group_size(cfg)):
        kind = mixer_kind(cfg, i)
        if kind == "attn":
            add("kv", i, kind="tokens", dtype=native, dims=(K, hd),
                token_bytes=2 * K * hd * native.itemsize, shareable=True)
        elif kind == "mla":
            C = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
            add("mla", i, kind="tokens", dtype=native, dims=(C,),
                token_bytes=C * native.itemsize, shareable=True)
        elif kind == "mamba":
            di, ds, dc, _ = _mam._dims(cfg)
            add("ssm", i, kind="state", dtype=jnp.dtype(jnp.float32),
                shape=(di, ds), shareable=False)
            add("conv", i, kind="state", dtype=native, shape=(dc - 1, di),
                shareable=False)
        elif kind == "rwkv":
            rhd = cfg.ssm.rwkv_head_dim
            H = cfg.d_model // rhd
            add("wkv", i, kind="state", dtype=jnp.dtype(jnp.float32),
                shape=(H, rhd, rhd), shareable=False)
            add("shift", i, kind="state", dtype=native,
                shape=(2, cfg.d_model), shareable=False)
        else:  # pragma: no cover — guarded by supports_paged
            raise ValueError(f"{cfg.name}: sub-layer {i} ({kind}) has no "
                             "page plane")
    return planes


# ---------------------------------------------------------------------------
# Forward (training): full sequence, no cache
# ---------------------------------------------------------------------------
def _sp_constrain(x, shard_axes):
    """Sequence parallelism (Megatron SP): the residual stream carried across
    layer groups is sequence-sharded over the 'model' axis, so the per-layer
    stack saved for the scan backward is 1/TP of the naive size (the dominant
    train-memory term — see EXPERIMENTS.md §Perf). XLA inserts the
    all-gather/reduce-scatter transitions around attention/MLP."""
    if not shard_axes or not shard_axes.get("sp"):
        return x
    from repro.models.losses import constrain
    mesh = shard_axes["mesh"]
    tp_n = dict(zip(mesh.axis_names, mesh.devices.shape))[shard_axes["tp"]]
    if x.ndim >= 3 and x.shape[1] % tp_n == 0 and x.shape[1] >= tp_n:
        return constrain(x, (shard_axes["dp"], shard_axes["tp"], None))
    return x


def _group_train(gp, cfg: ModelConfig, x, shard_axes=None):
    aux = jnp.zeros((), jnp.float32)
    x = _sp_constrain(x, shard_axes)
    for i in range(group_size(cfg)):
        p = gp[f"sub{i}"]
        kind = mixer_kind(cfg, i)
        if kind == "rwkv":
            st = rwkv_mod.init_rwkv_state(cfg, x.shape[0], x.dtype)
            x, _ = rwkv_mod.rwkv_block(p["mix"], cfg, x, st,
                                       {"n1": p["n1"], "n2": p["n2"]})
            continue
        h = rms_norm(p["n1"], x, cfg.rmsnorm_eps)
        if kind == "mamba":
            st = mamba_mod.init_mamba_state(cfg, x.shape[0], x.dtype)
            h, _ = mamba_mod.mamba_forward(p["mix"], cfg, h, st,
                                           shard_axes=shard_axes)
        elif kind == "mla":
            h = mla_mod.mla_full(p["mix"], cfg, h)
        else:
            h = attn.attention_full(p["mix"], cfg, h, window=layer_window(cfg, i))
        x = x + h
        fk = ffn_kind(cfg, i)
        if fk:
            h = rms_norm(p["n2"], x, cfg.rmsnorm_eps)
            if fk == "moe":
                h, a = moe_apply(p["ffn"], cfg, h, shard_axes=shard_axes)
                aux = aux + a
            else:
                h = mlp(p["ffn"], cfg, h)
            x = x + h
    return _sp_constrain(x, shard_axes), aux


def forward(params, cfg: ModelConfig, tokens, *, prefix_embeds=None,
            remat: bool = False, shard_axes=None):
    """tokens: (B,T) -> logits (B, T(+P), V); returns (logits, aux_loss)."""
    from repro.models.losses import constrain
    x = embed(params["embed"], cfg, tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    if shard_axes:
        x = constrain(x, (shard_axes["dp"], None, None))

    def body_fn(gp, cfg, x):
        return _group_train(gp, cfg, x, shard_axes)
    body = body_fn
    if remat:
        # full remat: at d_ff up to 8*d_model, saving projection outputs
        # (dots_*_saveable policies) costs ~5.6 GB/layer-stack at this scale;
        # recomputing the whole group body in the backward is the right
        # trade (see EXPERIMENTS.md §Perf iteration log)
        body = jax.checkpoint(body, static_argnums=(1,))

    def scan_body(carry, gp):
        x, aux = carry
        x, a = body(gp, cfg, x)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(scan_body, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"])
    x = rms_norm(params["final_norm"], x, cfg.rmsnorm_eps)
    logits = unembed(params["embed"], cfg, x)
    return logits, aux


def loss_fn(params, cfg: ModelConfig, batch: dict, *, remat: bool = False,
            shard_axes=None):
    """Next-token cross-entropy (+ MoE aux). batch: tokens (B,T), prefix_embeds?"""
    from repro.models.losses import shifted_xent
    tokens = batch["tokens"]
    logits, aux = forward(params, cfg, tokens,
                          prefix_embeds=batch.get("prefix_embeds"),
                          remat=remat, shard_axes=shard_axes)
    P = logits.shape[1] - tokens.shape[1]
    loss = shifted_xent(logits[:, P:], tokens, shard_axes)
    if cfg.moe is not None:
        loss = loss + cfg.moe.aux_loss_coef * aux / max(cfg.n_layers // cfg.moe.moe_every, 1)
    return loss


# ---------------------------------------------------------------------------
# Prefill / decode
# ---------------------------------------------------------------------------
def _group_prefill(gp, cfg: ModelConfig, x, cache, pos_offset=0, shard_axes=None):
    """Run a full-sequence pass, producing filled caches."""
    new_cache = {}
    for i in range(group_size(cfg)):
        p = gp[f"sub{i}"]
        kind = mixer_kind(cfg, i)
        c = cache[f"sub{i}"]
        if kind == "rwkv":
            x, nc = rwkv_mod.rwkv_block(p["mix"], cfg, x,
                                        rwkv_mod.RWKVState(*c),
                                        {"n1": p["n1"], "n2": p["n2"]})
            new_cache[f"sub{i}"] = nc
            continue
        h = rms_norm(p["n1"], x, cfg.rmsnorm_eps)
        if kind == "mamba":
            h, nc = mamba_mod.mamba_forward(p["mix"], cfg, h,
                                            mamba_mod.MambaState(*c),
                                            shard_axes=shard_axes)
        elif kind == "mla":
            h, (c_kv, k_rope) = mla_mod.mla_full(p["mix"], cfg, h, return_cache=True)
            nc = mla_mod.fill_mla_cache(mla_mod.MLACache(*c), c_kv, k_rope)
        else:
            w = layer_window(cfg, i)
            h, (k, v) = attn.attention_full(p["mix"], cfg, h, window=w,
                                            return_kv=True)
            nc = attn.fill_kv_cache(attn.KVCache(*c), k, v, w)
        x = x + h
        fk = ffn_kind(cfg, i)
        if fk:
            h = rms_norm(p["n2"], x, cfg.rmsnorm_eps)
            h = (moe_apply(p["ffn"], cfg, h, shard_axes=shard_axes)[0]
                 if fk == "moe" else mlp(p["ffn"], cfg, h))
            x = x + h
        new_cache[f"sub{i}"] = nc
    return x, new_cache


def prefill(params, cfg: ModelConfig, tokens, cache, *, prefix_embeds=None,
            shard_axes=None):
    """tokens (B,T) + empty cache -> (last-token logits (B,V), filled cache)."""
    x = embed(params["embed"], cfg, tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)

    def scan_body(x, xs):
        gp, c = xs
        x, nc = _group_prefill(gp, cfg, x, c, shard_axes=shard_axes)
        return x, nc

    x, new_cache = jax.lax.scan(scan_body, x, (params["blocks"], cache))
    x = rms_norm(params["final_norm"], x, cfg.rmsnorm_eps)
    logits = unembed(params["embed"], cfg, x[:, -1:])[:, 0]
    return logits, new_cache


# ---------------------------------------------------------------------------
# Paged prefill / decode: KV lives on AquaTensor pages (serving runtime)
# ---------------------------------------------------------------------------
def _ffn_apply(p, cfg: ModelConfig, x, i: int, *, dropless: bool = False,
               shard_axes=None):
    fk = ffn_kind(cfg, i)
    if not fk:
        return x
    h = rms_norm(p["n2"], x, cfg.rmsnorm_eps)
    if fk == "moe":
        h = moe_apply(p["ffn"], cfg, h, dropless=dropless,
                      shard_axes=shard_axes)[0]
    else:
        h = mlp(p["ffn"], cfg, h)
    return x + h


# One representative arch per paged state family — attention (qwen), MLA
# (deepseek), hybrid attention+Mamba (jamba), RWKV6 — the cross-family axis
# the paging/mesh bit-exactness suites sweep.
PAGED_FAMILY_ARCHS = ("qwen1.5-0.5b", "deepseek-v2-lite-16b",
                      "jamba-v0.1-52b", "rwkv6-3b")

# Traces of the serving entry points, keyed by name. The counter bumps as a
# Python side effect INSIDE the traced function body, so it advances once per
# jit trace (shape bucket), not per call — the CI retrace guard asserts it
# stays flat across a mixed-length workload.
TRACE_COUNTS: Counter = Counter()


def trace_counts() -> dict:
    return dict(TRACE_COUNTS)


def reset_trace_counts():
    TRACE_COUNTS.clear()


def _plane_state_rwkv(pools, tables_g, j, b=None):
    """Assemble an RWKVState from the state pools. ``b=None``: B=1 prefill
    (scalar slots, add the batch axis); else batched decode (slots (B,))."""
    ws, ss = tables_g["wkv"][j], tables_g["shift"][j]
    if b is None:
        return rwkv_mod.RWKVState(pools["wkv"][ws][None],
                                  pools["shift"][ss][0][None],
                                  pools["shift"][ss][1][None])
    return rwkv_mod.RWKVState(pools["wkv"][ws],
                              pools["shift"][ss][:, 0],
                              pools["shift"][ss][:, 1])


def _store_state_rwkv(pools, tables_g, j, nst, b=None):
    ws, ss = tables_g["wkv"][j], tables_g["shift"][j]
    shift = jnp.stack([nst.tm_shift, nst.cm_shift],
                      axis=-2).astype(pools["shift"].dtype)
    if b is None:
        pools["wkv"] = pools["wkv"].at[ws].set(nst.wkv[0])
        pools["shift"] = pools["shift"].at[ss].set(shift[0])
    else:
        pools["wkv"] = pools["wkv"].at[ws].set(nst.wkv)
        pools["shift"] = pools["shift"].at[ss].set(shift)
    return pools


def _group_fwd_paged(gp, cfg: ModelConfig, x, pools, tables_g, *,
                     q_start=None, n_real=None, pos=None,
                     read_pps: Optional[int], impl: str):
    """One layer group against the page pools — shared by chunked prefill
    (B=1, ``q_start``/``n_real`` set) and batched decode (``pos`` set).

    Sub-layer kind is static in the position within the group, so each
    position statically dispatches to its plane(s); ``idx`` tracks each
    plane's running sub-index, matching the runtime's table row order.
    """
    prefill = pos is None
    b = None if prefill else x.shape[0]
    idx: Counter = Counter()
    for i in range(group_size(cfg)):
        p = gp[f"sub{i}"]
        kind = mixer_kind(cfg, i)
        if kind == "rwkv":
            j = idx["wkv"]
            idx["wkv"] += 1
            st = _plane_state_rwkv(pools, tables_g, j, b)
            x, nst = rwkv_mod.rwkv_block(p["mix"], cfg, x, st,
                                         {"n1": p["n1"], "n2": p["n2"]},
                                         n_real=n_real)
            pools = _store_state_rwkv(pools, tables_g, j, nst, b)
            continue
        h = rms_norm(p["n1"], x, cfg.rmsnorm_eps)
        if kind == "mamba":
            j = idx["ssm"]
            idx["ssm"] += 1
            ss, cs = tables_g["ssm"][j], tables_g["conv"][j]
            if prefill:
                st = mamba_mod.MambaState(pools["ssm"][ss][None],
                                          pools["conv"][cs][None])
                h, nst = mamba_mod.mamba_forward(p["mix"], cfg, h, st,
                                                 n_real=n_real)
                pools["ssm"] = pools["ssm"].at[ss].set(nst.ssm[0])
                pools["conv"] = pools["conv"].at[cs].set(
                    nst.conv[0].astype(pools["conv"].dtype))
            else:
                st = mamba_mod.MambaState(pools["ssm"][ss], pools["conv"][cs])
                h, nst = mamba_mod.mamba_decode(p["mix"], cfg, h, st)
                pools["ssm"] = pools["ssm"].at[ss].set(nst.ssm)
                pools["conv"] = pools["conv"].at[cs].set(
                    nst.conv.astype(pools["conv"].dtype))
        elif kind == "mla":
            j = idx["mla"]
            idx["mla"] += 1
            if prefill:
                h, pools["mla"] = mla_mod.mla_prefill_chunk(
                    p["mix"], cfg, h, pools["mla"], tables_g["mla"][j],
                    q_start, read_pps=read_pps)
            else:
                h, pools["mla"] = mla_mod.mla_decode_paged(
                    p["mix"], cfg, h, pools["mla"], tables_g["mla"][j], pos)
        else:
            j = idx["kv"]
            idx["kv"] += 1
            if prefill:
                h, pools["kv"] = attn.attention_prefill_chunk(
                    p["mix"], cfg, h, pools["kv"], tables_g["kv"][j], q_start,
                    read_pps=read_pps, impl=impl)
            else:
                h, pools["kv"] = attn.attention_decode_paged(
                    p["mix"], cfg, h, pools["kv"], tables_g["kv"][j], pos,
                    impl=impl)
        x = x + h
        x = _ffn_apply(p, cfg, x, i, dropless=True)
    return x, pools


def prefill_chunk_paged(params, cfg: ModelConfig, tokens, pools,
                        block_tables, q_start, last_index, *,
                        prefix_embeds=None,
                        read_pps: Optional[int] = None,
                        impl: str = "pallas"):
    """Prefill ONE CHUNK of one request, writing its state straight into the
    page pools — any family, one code path.

    tokens: (1,Tc) — the chunk, bucket-padded. Garbage rows past the real
    length are masked causally for attention/MLA (and overwritten by later
    chunks/decode); for recurrent planes ``n_real = last_index + 1`` zeroes
    their state updates (identity transition), so the carried Mamba/RWKV
    state is bit-exactly the state after the last real token.
    pools: {plane: pool} LOCAL pools (see ``paged_layout``);
    block_tables: {plane: (G, n_sub, ...)} — token planes ``(..., pps_pad)``
    int32 physical slots from position 0, dummy-padded; state planes bare
    physical slots. q_start / last_index: () int32 (traced) — the chunk's
    absolute start position and the row whose logits the caller wants.
    prefix_embeds: (1, P, d) VLM prefix rows — rows of the chunk at absolute
    positions < P take these embeddings instead of the token embedding (the
    engine routes the q_start == 0 chunks of a VLM prompt through here).
    -> (logits (1,V) of ``last_index``, updated pools)

    Whole-prompt prefill is the degenerate single-chunk call; any chunk
    split yields bit-identical logits (split-invariant page reduction for
    attention/MLA, exact state handoff for Mamba/RWKV). MoE FFNs run
    DROPLESS so a token's routing cannot depend on its chunk's occupancy.
    """
    assert supports_paged(cfg), f"{cfg.name}: not paged-servable"
    assert tokens.shape[0] == 1, "chunked prefill is per-request"
    TRACE_COUNTS["prefill_chunk"] += 1
    q_start = jnp.asarray(q_start, jnp.int32).reshape(())
    last_index = jnp.asarray(last_index, jnp.int32).reshape(())
    n_real = last_index + 1
    x = embed(params["embed"], cfg, tokens)
    if prefix_embeds is not None:
        P = prefix_embeds.shape[1]
        rows = q_start + jnp.arange(tokens.shape[1], dtype=jnp.int32)
        pre = jnp.take(prefix_embeds[0], jnp.clip(rows, 0, P - 1), axis=0)
        x = jnp.where((rows < P)[None, :, None], pre[None].astype(x.dtype), x)

    def scan_body(carry, xs):
        x, pools = carry
        gp, tg = xs
        x, pools = _group_fwd_paged(gp, cfg, x, dict(pools), tg,
                                    q_start=q_start, n_real=n_real,
                                    read_pps=read_pps, impl=impl)
        return (x, pools), None

    (x, pools), _ = jax.lax.scan(scan_body, (x, pools),
                                 (params["blocks"], block_tables))
    x = rms_norm(params["final_norm"], x, cfg.rmsnorm_eps)
    last = jax.lax.dynamic_slice_in_dim(x, last_index, 1, axis=1)
    logits = unembed(params["embed"], cfg, last)[:, 0]
    return logits, pools


@functools.lru_cache(maxsize=None)
def _prefill_chunk_jit(cfg: ModelConfig, impl: str, read_pps: Optional[int]):
    """One compiled program per (config, impl, shape bucket)."""
    return jax.jit(lambda params, tokens, pools, bt, q_start, last, prefix:
                   prefill_chunk_paged(params, cfg, tokens, pools, bt,
                                       q_start, last, prefix_embeds=prefix,
                                       read_pps=read_pps, impl=impl))


def prefill_chunk_paged_jit(params, cfg: ModelConfig, tokens, pools,
                            block_tables, q_start, last_index, *,
                            prefix_embeds=None,
                            read_pps: Optional[int] = None,
                            impl: str = "pallas"):
    """Jit'd chunk prefill: callers pass bucket-padded shapes, so the trace
    count is bounded by the bucket ladder, not the prompt-length set."""
    return _prefill_chunk_jit(cfg, impl, read_pps)(params, tokens, pools,
                                                   block_tables, q_start,
                                                   last_index, prefix_embeds)


def decode_step_paged(params, cfg: ModelConfig, pools, block_tables,
                      tokens, pos, *, impl: str = "pallas"):
    """One token for every sequence against the page pools — any family.

    tokens/pos: (B,); pools: {plane: pool}; block_tables: {plane:
    (G, n_sub, B[, pps])} int32 physical LOCAL slots (token planes carry the
    trailing pps axis; state planes are one slot per layer per lane; idle
    lanes point at the plane's scratch page). -> (logits (B,V), pools).
    Decode attention goes through kernels/paged_attention (interpret on CPU)
    when ``impl='pallas'``; ``impl='xla'`` uses the jnp oracle. MLA and the
    recurrent planes read/scatter the pools directly in jnp (shape-stable).
    """
    assert supports_paged(cfg), f"{cfg.name}: not paged-servable"
    TRACE_COUNTS["decode_step"] += 1
    x = embed(params["embed"], cfg, tokens[:, None])

    def scan_body(carry, xs):
        x, pools = carry
        gp, tg = xs
        x, pools = _group_fwd_paged(gp, cfg, x, dict(pools), tg, pos=pos,
                                    read_pps=None, impl=impl)
        return (x, pools), None

    (x, pools), _ = jax.lax.scan(scan_body, (x, pools),
                                 (params["blocks"], block_tables))
    x = rms_norm(params["final_norm"], x, cfg.rmsnorm_eps)
    logits = unembed(params["embed"], cfg, x)[:, 0]
    return logits, pools


@functools.lru_cache(maxsize=None)
def _decode_step_jit(cfg: ModelConfig, impl: str):
    return jax.jit(lambda params, pools, bt, tokens, pos: decode_step_paged(
        params, cfg, pools, bt, tokens, pos, impl=impl))


def decode_step_paged_jit(params, cfg: ModelConfig, pools, block_tables,
                          tokens, pos, *, impl: str = "pallas"):
    """Jit'd paged decode: batch lanes and block tables have fixed padded
    shapes, so the whole step compiles exactly once per (config, impl)."""
    return _decode_step_jit(cfg, impl)(params, pools, block_tables, tokens,
                                       pos)


def _group_fwd_mixed(gp, cfg: ModelConfig, x, pools, tables_g, *,
                     q_starts, n_reals, n_decode: int,
                     read_pps: Optional[int], impl: str):
    """One layer group of a PACKED engine step: rows ``[:n_decode]`` are
    decode lanes (single real token at column 0), the rest prefill chunk
    rows — every plane dispatches per row REGION so each mode keeps its
    per-request math bit-exactly (absorbed MLA decode, batched recurrent
    decode steps, per-lane ``n_real`` identity transitions for chunk rows),
    while the attention plane serves every row in ONE fused kernel launch.

    tables_g: token planes ``(n_sub, R, pps_pad)``, state planes
    ``(n_sub, R)`` — one row per packed lane, scratch for idle/pad rows.
    """
    R, Tc, _ = x.shape
    nd, Rp = n_decode, x.shape[0] - n_decode
    idx: Counter = Counter()

    def merge(h_dec, h_chunk, d):
        if h_dec is not None and Tc > 1:
            h_dec = jnp.concatenate(
                [h_dec, jnp.zeros((nd, Tc - 1, d), h_dec.dtype)], axis=1)
        parts = [h for h in (h_dec, h_chunk) if h is not None]
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)

    for i in range(group_size(cfg)):
        p = gp[f"sub{i}"]
        kind = mixer_kind(cfg, i)
        if kind == "rwkv":
            j = idx["wkv"]
            idx["wkv"] += 1
            ws, ss = tables_g["wkv"][j], tables_g["shift"][j]
            norms = {"n1": p["n1"], "n2": p["n2"]}
            x_dec = x_chunk = None
            if nd:
                st = rwkv_mod.RWKVState(pools["wkv"][ws[:nd]],
                                        pools["shift"][ss[:nd]][:, 0],
                                        pools["shift"][ss[:nd]][:, 1])
                x_dec, nst = rwkv_mod.rwkv_block(p["mix"], cfg, x[:nd, :1],
                                                 st, norms)
                shift = jnp.stack([nst.tm_shift, nst.cm_shift],
                                  axis=-2).astype(pools["shift"].dtype)
                pools["wkv"] = pools["wkv"].at[ws[:nd]].set(nst.wkv)
                pools["shift"] = pools["shift"].at[ss[:nd]].set(shift)
            if Rp:
                st = rwkv_mod.RWKVState(pools["wkv"][ws[nd:]],
                                        pools["shift"][ss[nd:]][:, 0],
                                        pools["shift"][ss[nd:]][:, 1])
                x_chunk, nst = rwkv_mod.rwkv_block(p["mix"], cfg, x[nd:], st,
                                                   norms, n_real=n_reals[nd:])
                shift = jnp.stack([nst.tm_shift, nst.cm_shift],
                                  axis=-2).astype(pools["shift"].dtype)
                pools["wkv"] = pools["wkv"].at[ws[nd:]].set(nst.wkv)
                pools["shift"] = pools["shift"].at[ss[nd:]].set(shift)
            # the rwkv block carries its own residual: decode rows keep
            # their garbage tail columns unchanged
            if x_dec is not None and Tc > 1:
                x_dec = jnp.concatenate([x_dec, x[:nd, 1:]], axis=1)
            parts = [h for h in (x_dec, x_chunk) if h is not None]
            x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)
            continue
        h = rms_norm(p["n1"], x, cfg.rmsnorm_eps)
        if kind == "mamba":
            j = idx["ssm"]
            idx["ssm"] += 1
            ss, cs = tables_g["ssm"][j], tables_g["conv"][j]
            h_dec = h_chunk = None
            if nd:
                st = mamba_mod.MambaState(pools["ssm"][ss[:nd]],
                                          pools["conv"][cs[:nd]])
                h_dec, nst = mamba_mod.mamba_decode(p["mix"], cfg,
                                                    h[:nd, :1], st)
                pools["ssm"] = pools["ssm"].at[ss[:nd]].set(nst.ssm)
                pools["conv"] = pools["conv"].at[cs[:nd]].set(
                    nst.conv.astype(pools["conv"].dtype))
            if Rp:
                st = mamba_mod.MambaState(pools["ssm"][ss[nd:]],
                                          pools["conv"][cs[nd:]])
                h_chunk, nst = mamba_mod.mamba_forward(p["mix"], cfg, h[nd:],
                                                       st,
                                                       n_real=n_reals[nd:])
                pools["ssm"] = pools["ssm"].at[ss[nd:]].set(nst.ssm)
                pools["conv"] = pools["conv"].at[cs[nd:]].set(
                    nst.conv.astype(pools["conv"].dtype))
            h = merge(h_dec, h_chunk, h.shape[-1])
        elif kind == "mla":
            j = idx["mla"]
            idx["mla"] += 1
            h, pools["mla"] = mla_mod.mla_mixed_paged(
                p["mix"], cfg, h, pools["mla"], tables_g["mla"][j],
                q_starts, n_reals, n_decode=nd, read_pps=read_pps)
        else:
            j = idx["kv"]
            idx["kv"] += 1
            h, pools["kv"] = attn.attention_mixed_paged(
                p["mix"], cfg, h, pools["kv"], tables_g["kv"][j],
                q_starts, n_reals, n_decode=nd, read_pps=read_pps, impl=impl)
        x = x + h
        x = _ffn_apply(p, cfg, x, i, dropless=True)
    return x, pools


def serve_step_paged(params, cfg: ModelConfig, tokens, pools, block_tables,
                     q_starts, n_reals, *, n_decode: int, prefix_embeds=None,
                     read_pps: Optional[int] = None, impl: str = "pallas"):
    """ONE fused engine step: every scheduled decode token and every
    request's prompt chunk in a single jitted call — any family.

    tokens: (R, Tc) packed rows. Rows ``[:n_decode]`` are decode lanes
    (``Tc`` is 1 on all-decode steps): the lane's next token at column 0,
    ``q_starts[r]`` its position, ``n_reals[r] = 1``; idle lanes hold token
    0 at position 0 against the scratch page. Rows ``[n_decode:]`` are
    prefill chunk rows: ``n_reals[r]`` prompt tokens from absolute position
    ``q_starts[r]``, bucket-padded in both axes (``n_real == 0`` marks a
    pad row pointing at scratch).
    pools: {plane: pool} LOCAL pools; block_tables: token planes
    ``(G, n_sub, R, pps_pad)`` int32 physical slots from position 0, state
    planes ``(G, n_sub, R)`` bare slots — one row per packed lane.
    prefix_embeds: (R, P, d) VLM prefix rows (zeros for non-VLM rows) —
    chunk rows covering absolute positions < P take these embeddings.
    -> (logits (R, V) of each row's last real token, updated pools)

    Row r's logits are bit-identical to the per-request entry point that
    row replaces (``decode_step_paged`` / ``prefill_chunk_paged``): each
    plane dispatches decode and chunk row regions through its per-request
    math, and the fused attention kernel's per-row reduction order is the
    per-request kernels'. What changes is the launch count: one jitted
    dispatch and one attention launch per layer for the WHOLE step, instead
    of one call per admitted request's chunk plus one more for decode. On
    TPU that launch is the COMPILED ``paged_mixed_attention_pool`` pass —
    megacore-partitioned across the packed row axis (still bit-identical:
    partitioning splits whole rows, never a row's page loop); interpret
    mode is CPU-only (``ops._on_cpu``).
    """
    assert supports_paged(cfg), f"{cfg.name}: not paged-servable"
    TRACE_COUNTS["serve_step"] += 1
    R, Tc = tokens.shape
    q_starts = jnp.asarray(q_starts, jnp.int32).reshape(-1)
    n_reals = jnp.asarray(n_reals, jnp.int32).reshape(-1)
    x = embed(params["embed"], cfg, tokens)
    if prefix_embeds is not None:
        P = prefix_embeds.shape[1]
        rows = q_starts[:, None] + jnp.arange(Tc, dtype=jnp.int32)[None, :]
        pre = jnp.take_along_axis(prefix_embeds,
                                  jnp.clip(rows, 0, P - 1)[:, :, None],
                                  axis=1)
        x = jnp.where((rows < P)[:, :, None], pre.astype(x.dtype), x)

    def scan_body(carry, xs):
        x, pools = carry
        gp, tg = xs
        x, pools = _group_fwd_mixed(gp, cfg, x, dict(pools), tg,
                                    q_starts=q_starts, n_reals=n_reals,
                                    n_decode=n_decode, read_pps=read_pps,
                                    impl=impl)
        return (x, pools), None

    (x, pools), _ = jax.lax.scan(scan_body, (x, pools),
                                 (params["blocks"], block_tables))
    x = rms_norm(params["final_norm"], x, cfg.rmsnorm_eps)
    last = jnp.take_along_axis(x, jnp.clip(n_reals - 1, 0, Tc - 1)
                               [:, None, None], axis=1)
    logits = unembed(params["embed"], cfg, last)[:, 0]
    return logits, pools


@functools.lru_cache(maxsize=None)
def _serve_step_jit(cfg: ModelConfig, impl: str, read_pps: Optional[int],
                    n_decode: int):
    """One compiled program per (config, impl, n_decode, shape bucket)."""
    return jax.jit(lambda params, tokens, pools, bt, q_starts, n_reals, pre:
                   serve_step_paged(params, cfg, tokens, pools, bt, q_starts,
                                    n_reals, n_decode=n_decode,
                                    prefix_embeds=pre, read_pps=read_pps,
                                    impl=impl))


def serve_step_paged_jit(params, cfg: ModelConfig, tokens, pools,
                         block_tables, q_starts, n_reals, *, n_decode: int,
                         prefix_embeds=None, read_pps: Optional[int] = None,
                         impl: str = "pallas"):
    """Jit'd fused step: callers pass bucket-padded row counts and chunk
    lengths, so the trace count is bounded by the (rows x tokens) bucket
    ladder — flat in the number of admitted requests."""
    return _serve_step_jit(cfg, impl, read_pps, n_decode)(
        params, tokens, pools, block_tables, q_starts, n_reals,
        prefix_embeds)


def _group_decode(gp, cfg: ModelConfig, x, cache, pos, shard_axes=None):
    new_cache = {}
    for i in range(group_size(cfg)):
        p = gp[f"sub{i}"]
        kind = mixer_kind(cfg, i)
        c = cache[f"sub{i}"]
        if kind == "rwkv":
            x, nc = rwkv_mod.rwkv_block(p["mix"], cfg, x, rwkv_mod.RWKVState(*c),
                                        {"n1": p["n1"], "n2": p["n2"]})
            new_cache[f"sub{i}"] = nc
            continue
        h = rms_norm(p["n1"], x, cfg.rmsnorm_eps)
        if kind == "mamba":
            h, nc = mamba_mod.mamba_decode(p["mix"], cfg, h, mamba_mod.MambaState(*c))
        elif kind == "mla":
            h, nc = mla_mod.mla_decode(p["mix"], cfg, h, mla_mod.MLACache(*c), pos)
        else:
            h, nc = attn.attention_decode(p["mix"], cfg, h, attn.KVCache(*c), pos,
                                          window=layer_window(cfg, i))
        x = x + h
        fk = ffn_kind(cfg, i)
        if fk:
            h = rms_norm(p["n2"], x, cfg.rmsnorm_eps)
            h = (moe_apply(p["ffn"], cfg, h, dropless=True,
                           shard_axes=shard_axes)[0] if fk == "moe"
                 else mlp(p["ffn"], cfg, h))
            x = x + h
        new_cache[f"sub{i}"] = nc
    return x, new_cache


def decode_step(params, cfg: ModelConfig, cache, tokens, pos, shard_axes=None):
    """One token for every sequence. tokens (B,), pos (B,) -> (logits (B,V), cache)."""
    x = embed(params["embed"], cfg, tokens[:, None])

    def scan_body(x, xs):
        gp, c = xs
        x, nc = _group_decode(gp, cfg, x, c, pos, shard_axes=shard_axes)
        return x, nc

    x, new_cache = jax.lax.scan(scan_body, x, (params["blocks"], cache))
    x = rms_norm(params["final_norm"], x, cfg.rmsnorm_eps)
    logits = unembed(params["embed"], cfg, x)[:, 0]
    return logits, new_cache
