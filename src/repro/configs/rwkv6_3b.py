"""rwkv6-3b [ssm] — RWKV-6 "Finch", attention-free, data-dependent decay.

32L d_model=2560 d_ff=8960 vocab=65536  [arXiv:2404.05892; hf]
rwkv head_dim=64 -> 40 heads. Dynamic context = recurrent state, O(1) per request.
"""
from repro.configs.base import ModelConfig, SSM, SSMConfig, register

CONFIG = register(ModelConfig(
    name="rwkv6-3b",
    family=SSM,
    n_layers=32,
    d_model=2560,
    n_heads=40,                   # rwkv heads = d_model / rwkv_head_dim
    n_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    activation="relu_sq",         # rwkv channel-mix uses squared relu
    ssm=SSMConfig(rwkv_head_dim=64, rwkv_lora_decay=64, rwkv_lora_mix=32),
    max_seq_len=1 << 20,          # unbounded context (recurrent)
))
