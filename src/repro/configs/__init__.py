"""Per-architecture configs (one module per assigned arch)."""
import importlib

_ARCH_MODULES = [
    "internvl2_1b",
    "rwkv6_3b",
    "gemma_7b",
    "qwen1_5_0_5b",
    "minicpm_2b",
    "gemma3_12b",
    "deepseek_v2_lite_16b",
    "dbrx_132b",
    "whisper_tiny",
    "jamba_v0_1_52b",
    "aqua_paper",
]

_loaded = False


def load_all():
    global _loaded
    if _loaded:
        return
    for m in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{m}")
    _loaded = True


from repro.configs.base import (  # noqa: E402,F401
    ModelConfig, ShapeConfig, MoEConfig, MLAConfig, SSMConfig, HybridConfig,
    EncDecConfig, get_config, list_archs, smoke_config, shape_applicable,
    ALL_SHAPES, SHAPES_BY_NAME, TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K,
    DENSE, MOE, SSM, HYBRID, ENCDEC, VLM,
)
