"""gemma3-12b [dense] — 5:1 local:global attention, 128k ctx.  [hf; unverified tier]

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144, head_dim=256,
sliding window 1024 on local layers, every 6th layer global.
long_500k allowed: 40/48 layers are window-bounded; 8 global layers decode against
the paged cache (linear cost in S at decode).
"""
from repro.configs.base import ModelConfig, DENSE, register

CONFIG = register(ModelConfig(
    name="gemma3-12b",
    family=DENSE,
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    activation="geglu",
    tie_embeddings=True,
    embed_scale=True,
    use_qk_norm=True,
    sliding_window=1024,
    global_layer_every=6,
    rope_theta=1e6,
    max_seq_len=524288,
))
