"""jamba-v0.1-52b [hybrid] — Mamba+attn 1:7 interleave, MoE 16e top-2 every 2nd layer.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536  [arXiv:2403.19887; hf]
Period-8 blocks: layer index 4 within each period is attention, others Mamba.
"""
from repro.configs.base import (ModelConfig, HYBRID, HybridConfig, MoEConfig,
                                SSMConfig, register)

CONFIG = register(ModelConfig(
    name="jamba-v0.1-52b",
    family=HYBRID,
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    activation="swiglu",
    hybrid=HybridConfig(period=8, attn_index=4),
    moe=MoEConfig(n_experts=16, top_k=2, n_shared_experts=0,
                  d_ff_expert=14336, moe_every=2, capacity_factor=1.25),
    ssm=SSMConfig(mamba_d_state=16, mamba_d_conv=4, mamba_expand=2),
    max_seq_len=524288,
))
