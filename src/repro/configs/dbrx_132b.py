"""dbrx-132b [moe] — 16 experts top-4, fine-grained.  [hf:databricks/dbrx-base]

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352
"""
from repro.configs.base import ModelConfig, MOE, MoEConfig, register

CONFIG = register(ModelConfig(
    name="dbrx-132b",
    family=MOE,
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    activation="swiglu",
    moe=MoEConfig(n_experts=16, top_k=4, n_shared_experts=0,
                  d_ff_expert=10752, capacity_factor=1.25),
    rope_theta=5e5,
    max_seq_len=32768,
))
