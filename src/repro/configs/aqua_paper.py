"""The paper's own evaluated model configs (Tables 1-3), used by the serving
benchmarks that reproduce the paper's figures. llama-family dense decoders.
"""
from repro.configs.base import ModelConfig, DENSE, register

OPT_30B = register(ModelConfig(
    name="aqua-opt-30b", family=DENSE, n_layers=48, d_model=7168, n_heads=56,
    n_kv_heads=56, head_dim=128, d_ff=28672, vocab_size=50272,
    activation="gelu", max_seq_len=32768))

MISTRAL_7B = register(ModelConfig(
    name="aqua-mistral-7b", family=DENSE, n_layers=32, d_model=4096, n_heads=32,
    n_kv_heads=8, head_dim=128, d_ff=14336, vocab_size=32000,
    activation="swiglu", sliding_window=4096, max_seq_len=32768))

LLAMA2_13B = register(ModelConfig(
    name="aqua-llama2-13b", family=DENSE, n_layers=40, d_model=5120, n_heads=40,
    n_kv_heads=40, head_dim=128, d_ff=13824, vocab_size=32000,
    activation="swiglu", max_seq_len=4096))

CODELLAMA_34B = register(ModelConfig(
    name="aqua-codellama-34b", family=DENSE, n_layers=48, d_model=8192,
    n_heads=64, n_kv_heads=8, head_dim=128, d_ff=22016, vocab_size=32016,
    activation="swiglu", rope_theta=1e6, max_seq_len=16384))
