"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512, 64 routed top-6 + 2 shared.

27L d_model=2048 16H d_ff(expert)=1408 vocab=102400  [arXiv:2405.04434; hf]
Assignment note: the assignment lists both "64e top-6" and "160 routed"; published
V2-Lite is 64 routed + 2 shared, which we use (see DESIGN.md config notes).
MLA: the decode path uses matrix absorption -> cache is (kv_lora + rope_dim) = 576
per token, the arch most sensitive to AQUA's small-transfer coalescing insight.
"""
from repro.configs.base import ModelConfig, MOE, MoEConfig, MLAConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v2-lite-16b",
    family=MOE,
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    activation="swiglu",
    moe=MoEConfig(n_experts=64, top_k=6, n_shared_experts=2,
                  d_ff_expert=1408, capacity_factor=1.25),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    max_seq_len=32768,
))
