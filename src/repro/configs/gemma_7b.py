"""gemma-7b [dense] — GeGLU, head_dim=256, MQA on 2b (this is the 7b: 16 kv heads).

28L d_model=3072 16H (GQA kv=16) d_ff=24576 vocab=256000  [arXiv:2403.08295; hf]
"""
from repro.configs.base import ModelConfig, DENSE, register

CONFIG = register(ModelConfig(
    name="gemma-7b",
    family=DENSE,
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    activation="geglu",
    tie_embeddings=True,
    embed_scale=True,
    max_seq_len=32768,
))
