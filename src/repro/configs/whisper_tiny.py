"""whisper-tiny [audio] — enc-dec, conv frontend (STUB).  [arXiv:2212.04356]

4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865. The conv1d audio frontend is a
stub per the brief: input_specs() supplies precomputed frame embeddings
(B, 1500, d_model). Adaptation note: we use RMSNorm+RoPE in place of
LayerNorm+learned positions (uniform substrate); documented in DESIGN.md.
"""
from repro.configs.base import ModelConfig, ENCDEC, EncDecConfig, register

CONFIG = register(ModelConfig(
    name="whisper-tiny",
    family=ENCDEC,
    n_layers=4,                    # decoder layers
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    activation="gelu",
    encdec=EncDecConfig(n_encoder_layers=4, encoder_seq_len=1500,
                        max_decoder_len=448),
    max_seq_len=32768,             # synthetic decode_32k cell stresses the cache
))
