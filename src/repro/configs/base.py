"""Architecture + shape configuration for the repro framework.

Every assigned architecture is expressed as a :class:`ModelConfig`. The config is a
plain frozen dataclass so it can be hashed into jit static args and printed into
EXPERIMENTS.md verbatim.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Model families
# ---------------------------------------------------------------------------
DENSE = "dense"          # decoder-only transformer (GQA/MQA)
MOE = "moe"              # decoder-only transformer with MoE FFN
SSM = "ssm"              # RWKV-6 (attention-free)
HYBRID = "hybrid"        # Jamba: Mamba + attention interleave, MoE
ENCDEC = "encdec"        # Whisper: encoder-decoder
VLM = "vlm"              # LM backbone + stub patch-embedding frontend


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8                # routed experts
    top_k: int = 2
    n_shared_experts: int = 0         # always-on shared experts (DeepSeek style)
    d_ff_expert: int = 0              # per-expert FFN width (0 = use d_ff)
    capacity_factor: float = 1.25     # dispatch capacity factor
    router_jitter: float = 0.0
    moe_every: int = 1                # apply MoE every k-th layer (Jamba: 2)
    aux_loss_coef: float = 0.01


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 0              # 0 = direct q projection (V2-Lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """RWKV-6 / Mamba specific knobs."""
    # RWKV-6
    rwkv_head_dim: int = 64
    rwkv_lora_decay: int = 64         # rank of the data-dependent decay LoRA
    rwkv_lora_mix: int = 32           # rank of the token-shift interpolation LoRA
    # Mamba (Jamba)
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_dt_rank: int = 0            # 0 = d_model // 16


@dataclass(frozen=True)
class HybridConfig:
    """Jamba interleave: every `period` layers, `attn_index` is attention."""
    period: int = 8
    attn_index: int = 4


@dataclass(frozen=True)
class EncDecConfig:
    n_encoder_layers: int = 4
    encoder_seq_len: int = 1500      # whisper: 30s audio -> 1500 frames
    max_decoder_len: int = 448       # whisper decoder context


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 = d_model // n_heads
    # flavour knobs
    activation: str = "swiglu"        # swiglu | geglu | gelu | relu_sq
    qkv_bias: bool = False
    tie_embeddings: bool = False
    use_qk_norm: bool = False
    rope_theta: float = 10000.0
    rmsnorm_eps: float = 1e-6
    embed_scale: bool = False         # gemma: embeddings * sqrt(d_model)
    logit_softcap: float = 0.0
    attn_logit_softcap: float = 0.0
    # sliding window pattern: (local_window, pattern_period, global_every)
    sliding_window: int = 0           # 0 = full attention
    global_layer_every: int = 0       # gemma3: every 6th layer is global (5:1)
    # sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    # multimodality stub: number of prefix embedding positions fed by the
    # (stubbed) frontend, e.g. ViT patch embeddings for a VLM.
    n_prefix_embeds: int = 0
    max_seq_len: int = 8192
    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # implementation selection: "xla" einsum attention (used for CPU dry-run so
    # cost_analysis reflects true FLOPs) or "pallas" kernels (TPU target).
    attn_impl: str = "xla"

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_heads_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def is_attention_layer(self, layer_idx: int) -> bool:
        if self.family == SSM:
            return False
        if self.family == HYBRID:
            assert self.hybrid is not None
            return layer_idx % self.hybrid.period == self.hybrid.attn_index
        return True

    def is_global_attn_layer(self, layer_idx: int) -> bool:
        """gemma3-style 5 local : 1 global interleave."""
        if self.global_layer_every <= 0:
            return self.sliding_window == 0
        return (layer_idx + 1) % self.global_layer_every == 0

    def is_moe_layer(self, layer_idx: int) -> bool:
        if self.moe is None:
            return False
        return (layer_idx % self.moe.moe_every) == (self.moe.moe_every - 1)

    def param_count(self) -> int:
        """Analytic parameter count N (for 6*N*D model flops)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        nh, nkv, L = self.n_heads, self.n_kv_heads, self.n_layers
        total = v * d * (1 if self.tie_embeddings else 2)
        for i in range(L):
            if self.family == SSM:
                total += self._rwkv_layer_params()
                continue
            if self.family == HYBRID and not self.is_attention_layer(i):
                total += self._mamba_layer_params()
            elif self.mla is not None:
                total += self._mla_layer_params()
            else:
                total += d * (nh * hd) + 2 * d * (nkv * hd) + (nh * hd) * d
            # FFN
            if self.is_moe_layer(i):
                m = self.moe
                fe = m.d_ff_expert or f
                glu = 3 if self.activation in ("swiglu", "geglu") else 2
                total += d * m.n_experts          # router
                total += (m.n_experts + m.n_shared_experts) * glu * d * fe
            else:
                glu = 3 if self.activation in ("swiglu", "geglu") else 2
                total += glu * d * f
            total += 2 * d                         # norms
        if self.family == ENCDEC and self.encdec is not None:
            # encoder layers + cross attention already counted above only for
            # decoder; add encoder stack.
            enc = self.encdec.n_encoder_layers * (
                4 * d * (nh * hd) + 3 * d * f + 2 * d)
            # cross-attention per decoder layer
            enc += L * (4 * d * (nh * hd) + d)
            total += enc
        return total

    def _rwkv_layer_params(self) -> int:
        d = self.d_model
        s = self.ssm or SSMConfig()
        # time-mix: r,k,v,g,w projections + output + decay/mix LoRAs + channel mix
        tm = 5 * d * d + d * d + 2 * d * s.rwkv_lora_decay + 5 * 2 * d * s.rwkv_lora_mix
        cm = 2 * d * self.d_ff + self.d_ff * d
        return tm + cm

    def _mamba_layer_params(self) -> int:
        d = self.d_model
        s = self.ssm or SSMConfig()
        di = s.mamba_expand * d
        dtr = s.mamba_dt_rank or d // 16
        return (d * 2 * di + di * s.mamba_d_conv + di * (dtr + 2 * s.mamba_d_state)
                + dtr * di + di * s.mamba_d_state + di + di * d)

    def _mla_layer_params(self) -> int:
        d = self.d_model
        m = self.mla
        nh = self.n_heads
        qd = m.qk_nope_head_dim + m.qk_rope_head_dim
        q = d * nh * qd if m.q_lora_rank == 0 else d * m.q_lora_rank + m.q_lora_rank * nh * qd
        kv = d * (m.kv_lora_rank + m.qk_rope_head_dim)
        kv += m.kv_lora_rank * nh * (m.qk_nope_head_dim + m.v_head_dim)
        o = nh * m.v_head_dim * d
        return q + kv + o

    def dtype(self) -> jnp.dtype:
        return jnp.dtype(self.param_dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}

# archs that may run long_500k (sub-quadratic decode; see DESIGN.md skip list)
LONG_CONTEXT_OK = ("rwkv6-3b", "jamba-v0.1-52b", "gemma3-12b")


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a given (arch, shape) cell is runnable; else reason."""
    if shape.name == "long_500k" and cfg.name not in LONG_CONTEXT_OK:
        return False, "pure full-attention arch: 500k KV decode is quadratic-cost/OOM (DESIGN.md skip list)"
    return True, ""


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # import all config modules lazily
        from repro import configs as _c  # noqa
        _c.load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs():
    from repro import configs as _c
    _c.load_all()
    return sorted(_REGISTRY)


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        max_seq_len=128,
        param_dtype="float32",
        compute_dtype="float32",
    )
    if cfg.family == HYBRID:
        kw["n_layers"] = 8   # one full interleave period
    if cfg.global_layer_every:
        kw["n_layers"] = min(cfg.n_layers, 6)
        kw["sliding_window"] = 16
    if cfg.sliding_window and not cfg.global_layer_every:
        kw["sliding_window"] = 16
    if cfg.moe is not None:
        # capacity_factor = n_experts makes routing dropless, so smoke tests can
        # assert exact train/prefill/decode agreement (see moe_apply docstring).
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=2,
            n_shared_experts=min(cfg.moe.n_shared_experts, 1),
            d_ff_expert=128 if cfg.moe.d_ff_expert else 0,
            capacity_factor=4.0)
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(kv_lora_rank=64, q_lora_rank=0,
                              qk_nope_head_dim=32, qk_rope_head_dim=16,
                              v_head_dim=32)
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(
            cfg.ssm, rwkv_head_dim=32, rwkv_lora_decay=16, rwkv_lora_mix=8,
            mamba_d_state=8, mamba_dt_rank=8)
    if cfg.encdec is not None:
        kw["encdec"] = EncDecConfig(n_encoder_layers=2, encoder_seq_len=32,
                                    max_decoder_len=64)
    if cfg.n_prefix_embeds:
        kw["n_prefix_embeds"] = 8
    return cfg.replace(**kw)
