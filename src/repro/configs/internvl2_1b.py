"""internvl2-1b [vlm] — InternViT frontend (STUB) + InternLM2-1B-ish backbone.

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655  [arXiv:2404.16821; hf]
The ViT frontend is a stub per the brief: ``input_specs()`` supplies precomputed
patch embeddings (n_prefix_embeds positions of d_model).
"""
from repro.configs.base import ModelConfig, VLM, register

CONFIG = register(ModelConfig(
    name="internvl2-1b",
    family=VLM,
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    activation="swiglu",
    rope_theta=1e6,
    tie_embeddings=True,
    n_prefix_embeds=256,          # 16x16 ViT patch tokens from the stub frontend
    max_seq_len=32768,
))
