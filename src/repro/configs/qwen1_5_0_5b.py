"""qwen1.5-0.5b [dense] — QKV bias.  [hf:Qwen/Qwen1.5-0.5B; hf]

24L d_model=1024 16H (GQA kv=16) d_ff=2816 vocab=151936
"""
from repro.configs.base import ModelConfig, DENSE, register

CONFIG = register(ModelConfig(
    name="qwen1.5-0.5b",
    family=DENSE,
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=2816,
    vocab_size=151936,
    activation="swiglu",
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1e6,
    max_seq_len=32768,
))
