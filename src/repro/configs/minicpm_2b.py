"""minicpm-2b [dense] — WSD schedule (arch = llama-like).  [arXiv:2404.06395; hf]

40L d_model=2304 36H (GQA kv=36) d_ff=5760 vocab=122753
Training driver pairs this arch with the WSD LR schedule (training/optimizer.py).
"""
from repro.configs.base import ModelConfig, DENSE, register

CONFIG = register(ModelConfig(
    name="minicpm-2b",
    family=DENSE,
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab_size=122753,
    activation="swiglu",
    tie_embeddings=True,
    max_seq_len=32768,
))
