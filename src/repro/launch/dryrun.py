"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh)
cell against the production mesh, record memory/cost analysis and the
optimized HLO for the roofline pass.

MUST be first: jax locks the device count on first init, and only the
dry-run wants 512 placeholder host devices (smoke tests and benches see 1).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import gzip
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import (ALL_SHAPES, SHAPES_BY_NAME, get_config, list_archs,
                           shape_applicable)
from repro.distributed.sharding import ShardingRules
from repro.launch.mesh import make_production_mesh
from repro.models import api
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.train_loop import TrainConfig, make_train_step

ASSIGNED = [
    "internvl2-1b", "rwkv6-3b", "gemma-7b", "qwen1.5-0.5b", "minicpm-2b",
    "gemma3-12b", "deepseek-v2-lite-16b", "dbrx-132b", "whisper-tiny",
    "jamba-v0.1-52b",
]

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "../../../results/dryrun")


def input_specs(arch: str, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of the cell
    (weak-type-correct, shardable, no device allocation)."""
    cfg = get_config(arch)
    return api.make_inputs(cfg, SHAPES_BY_NAME[shape_name])


def build_cell(arch: str, shape_name: str, mesh, *, fsdp=True, remat=True,
               overrides=None):
    """Returns (jitted_fn, arg_specs tuple) for one cell."""
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES_BY_NAME[shape_name]
    # Serving: FSDP re-gathers weights EVERY decode step (HC2: 963 all-gathers
    # on the rwkv6 decode cell) — keep weights TP-resident unless they don't
    # fit (dbrx-132b: 264 GB bf16 / 16-way TP = 16.5 GB > HBM needs FSDP).
    if shape.kind != "train" and cfg.param_count() * 2 / 16 <= 4e9:
        fsdp = False
    rules = ShardingRules(mesh, cfg, fsdp=fsdp)
    pspecs = api.param_specs(cfg)
    pshard = rules.params(pspecs)
    inputs = api.make_inputs(cfg, shape)
    B = shape.global_batch

    if shape.kind == "train":
        ocfg = AdamWConfig(lr=3e-4)
        ospecs = jax.eval_shape(lambda p: adamw_init(p, ocfg), pspecs)
        oshard = rules.opt_state(ospecs, pspecs)
        shard_axes = {"dp": rules.dp, "tp": "model", "mesh": mesh, "sp": True}
        # 4 microbatches of 64 sequences: grad accumulation bounds activation
        # memory (temp/dev) at production batch 256 (see EXPERIMENTS.md §Perf)
        step = make_train_step(cfg, ocfg, TrainConfig(micro_batches=4,
                                                      remat=remat,
                                                      shard_axes=shard_axes))
        fn = jax.jit(step,
                     in_shardings=(pshard, oshard, rules.batch(inputs, B)),
                     out_shardings=(pshard, oshard, None),
                     donate_argnums=(0, 1))
        return fn, (pspecs, ospecs, inputs)

    cache_specs = inputs.pop("cache")
    cshard = rules.cache(cache_specs, B)
    shard_axes = {"dp": rules.dp, "tp": "model", "mesh": mesh}
    if shape.kind == "prefill":
        tokens = inputs.pop("tokens")
        extras = inputs

        def prefill_fn(params, tokens, cache, extras):
            return api.prefill(params, cfg, tokens, cache,
                               shard_axes=shard_axes, **extras)

        fn = jax.jit(prefill_fn,
                     in_shardings=(pshard, rules.batch(tokens, B), cshard,
                                   rules.batch(extras, B)),
                     out_shardings=(None, cshard),
                     donate_argnums=(2,))
        return fn, (pspecs, tokens, cache_specs, extras)

    # decode
    def decode_fn(params, cache, tokens, pos):
        return api.decode_step(params, cfg, cache, tokens, pos,
                               shard_axes=shard_axes)

    fn = jax.jit(decode_fn,
                 in_shardings=(pshard, cshard,
                               rules.batch(inputs["tokens"], B),
                               rules.batch(inputs["pos"], B)),
                 out_shardings=(None, cshard),
                 donate_argnums=(1,))
    return fn, (pspecs, cache_specs, inputs["tokens"], inputs["pos"])


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, out_dir: str,
             save_hlo: bool = True, fsdp=True, remat=True, overrides=None,
             tag: str = "") -> dict:
    mesh_name = "pod512" if multi_pod else "pod256"
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag}
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.monotonic()
    try:
        fn, specs = build_cell(arch, shape_name, mesh, fsdp=fsdp, remat=remat,
                               overrides=overrides)
        with mesh:
            lowered = fn.lower(*specs)
            t_lower = time.monotonic() - t0
            compiled = lowered.compile()
            t_compile = time.monotonic() - t0 - t_lower
            ma = compiled.memory_analysis()
            ca = compiled.cost_analysis()
        mem = {k: int(getattr(ma, k)) for k in
               ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "alias_size_in_bytes",
                "generated_code_size_in_bytes")}
        rec.update(status="ok", lower_s=round(t_lower, 1),
                   compile_s=round(t_compile, 1), memory=mem,
                   cost={k: float(v) for k, v in ca.items()
                         if isinstance(v, (int, float))})
        print(f"[dryrun] {mesh_name} {arch} {shape_name} {tag} OK "
              f"lower={t_lower:.1f}s compile={t_compile:.1f}s "
              f"args/dev={mem['argument_size_in_bytes']/1e9:.2f}GB "
              f"temp/dev={mem['temp_size_in_bytes']/1e9:.2f}GB "
              f"flops={rec['cost'].get('flops', 0):.3e}")
        if save_hlo:
            os.makedirs(out_dir, exist_ok=True)
            stem = f"{arch}_{shape_name}{('_' + tag) if tag else ''}"
            with gzip.open(os.path.join(out_dir, stem + ".hlo.gz"),
                           "wt") as f:
                f.write(compiled.as_text())
    except (ValueError, TypeError, KeyError, RuntimeError, MemoryError,
            NotImplementedError) as e:
        # the failure modes AOT lowering/compilation actually raises; a
        # blanket handler would also swallow KeyboardInterrupt/SystemExit
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
        print(f"[dryrun] {mesh_name} {arch} {shape_name} FAILED: {e}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--mesh", default="both", choices=["pod256", "pod512", "both"])
    ap.add_argument("--out", default=os.path.abspath(RESULTS_DIR))
    ap.add_argument("--no-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ASSIGNED
    shapes = [args.shape] if args.shape else [s.name for s in ALL_SHAPES]
    meshes = {"pod256": [False], "pod512": [True],
              "both": [False, True]}[args.mesh]

    for multi_pod in meshes:
        mesh_name = "pod512" if multi_pod else "pod256"
        out_dir = os.path.join(args.out, mesh_name)
        os.makedirs(out_dir, exist_ok=True)
        for arch in archs:
            for shape in shapes:
                rec_path = os.path.join(out_dir, f"{arch}_{shape}.json")
                if args.skip_existing and os.path.exists(rec_path):
                    continue
                rec = run_cell(arch, shape, multi_pod=multi_pod,
                               out_dir=out_dir, save_hlo=not args.no_hlo)
                with open(rec_path, "w") as f:
                    json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
