"""Training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ck

On a real TPU slice this runs under the production mesh with the dry-run's
sharding rules; on CPU (--smoke) it trains the reduced config unsharded.
Restart-safe: re-invoking with the same --ckpt-dir resumes from the newest
COMMITTED checkpoint.
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", choices=["cosine", "wsd"], default="cosine")
    ap.add_argument("--micro-batches", type=int, default=1)
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config, smoke_config
    from repro.training.data import DataConfig
    from repro.training.optimizer import (AdamWConfig, cosine_schedule,
                                          wsd_schedule)
    from repro.training.train_loop import TrainConfig, train

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    sched = (wsd_schedule if args.schedule == "wsd" else cosine_schedule)(
        args.lr, warmup=max(args.steps // 20, 1), total=args.steps)
    dcfg = DataConfig(seed=args.seed, batch=args.batch, seq_len=args.seq)
    ocfg = AdamWConfig(lr=sched)
    tcfg = TrainConfig(steps=args.steps, micro_batches=args.micro_batches,
                       remat=args.remat, ckpt_dir=args.ckpt_dir,
                       ckpt_every=args.ckpt_every)

    def on_step(step, stats):
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d}  loss {float(stats['loss']):.4f}  "
                  f"gnorm {float(stats['grad_norm']):.3f}  "
                  f"lr {float(stats['lr']):.2e}", flush=True)

    out = train(cfg, dcfg, ocfg, tcfg, seed=args.seed,
                hooks={"on_step": on_step})
    print(f"final loss: {out['losses'][-1]:.4f} "
          f"(first: {out['losses'][0]:.4f}); "
          f"straggler flags: {out['straggler_flags']}")


if __name__ == "__main__":
    main()
