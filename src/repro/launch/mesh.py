"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.

Axes:
  pod    2   data-parallel across pods (gradient all-reduce crosses DCI)
  data  16   data parallel / FSDP within a pod
  model 16   tensor/expert parallel within a pod (highest-bandwidth ICI ring)
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_debug_mesh(n_devices: int = 1):
    """A tiny mesh over however many (CPU) devices exist — for tests."""
    n = min(n_devices, len(jax.devices()))
    return jax.make_mesh((1, n, 1), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)


def dp_axes(mesh) -> tuple:
    """The axes a global batch shards over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis(mesh) -> str:
    return "model"
