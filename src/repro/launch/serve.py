"""Serving driver: host a model with FCFS or CFS+AQUA scheduling.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
        --scheduler cfs --offload fabric --requests 8
"""
from __future__ import annotations

import argparse

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--scheduler", choices=["fcfs", "cfs"], default="cfs")
    ap.add_argument("--offload", choices=["fabric", "host"], default="fabric")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-running", type=int, default=2)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--slice-tokens", type=int, default=3)
    args = ap.parse_args()

    from repro.configs import get_config, smoke_config
    from repro.core.aqua_tensor import HOST, REMOTE
    from repro.models import api
    from repro.serving.engine import ServingEngine

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, max_running=args.max_running, max_seq=96,
                        scheduler=args.scheduler,
                        slice_tokens=args.slice_tokens,
                        offload_tier=REMOTE if args.offload == "fabric" else HOST)
    # donor lease for the fabric tier (page pool or blob store, runtime-agnostic)
    eng.pager.add_remote_lease("donor0", 512 * 2048 * 4)
    print(f"runtime: unified paged state "
          f"(planes: {', '.join(eng.kv.planes)})")
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.submit(list(map(int, rng.integers(0, cfg.vocab_size, 8))),
                   args.max_new_tokens, arrival=0.1 * i)
    m = eng.run(2000)
    print(f"served {len(eng.finished)} requests in {m.steps} engine steps "
          f"({m.sim_time:.2f} simulated s)")
    print(f"prefills={m.prefills} preemptions={m.preemptions} "
          f"restores={m.restores}")
    print(f"max fairness spread: {max(m.fairness_trace)} tokens "
          f"(CFS bounds this; FCFS does not)")
    print("AQUA pager:", eng.pager.stats())


if __name__ == "__main__":
    main()
